"""Production-shaped scheduler demo: a million-page shard, pluggable
selection backends (fused single-pass select by default), decentralized
parameter refresh + the closed crawl->estimate->refresh loop, tiered lazy
evaluation, elastic bandwidth, checkpoint/restore with warm-start state.

    PYTHONPATH=src python examples/crawl_at_scale.py [--pages 1048576]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tables
from repro.sched import backends as be
from repro.sched.service import CrawlScheduler
from repro.sched.tiered import init_tiers, tiered_select
from repro.sim import uniform_instance
from repro import checkpoint as ckpt

BACKENDS = {
    "fused": lambda: be.FusedBackend(),
    "table": lambda: be.TableBackend(table_grid=64),
    "dense": lambda: be.DenseBackend(),
    "kernel": lambda: be.KernelBackend(),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pages", type=int, default=1 << 20)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--budget", type=float, default=4096.0)
    ap.add_argument("--ckpt", default="/tmp/repro_sched_ckpt")
    ap.add_argument("--select", choices=sorted(BACKENDS), default="fused",
                    help="selection backend (fused = packed single-pass "
                         "select, exact; table = App. G exposure tables)")
    args = ap.parse_args()

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), args.pages)
    sched = CrawlScheduler(env, mesh, bandwidth=args.budget,
                           backend=BACKENDS[args.select]())
    zero_cis = jnp.zeros((args.pages,), jnp.int32)

    print(f"pages={args.pages}, budget={args.budget}/round, "
          f"devices={mesh.size}, backend={args.select}")
    t0 = time.perf_counter()
    for r in range(args.rounds):
        ids, vals = sched.ingest_and_schedule(zero_cis)
        if r == args.rounds // 2:
            # elastic bandwidth (paper App. D): no recomputation at all
            sched.set_bandwidth(args.budget * 1.5)
            print(f"  round {r}: bandwidth -> {sched.bandwidth} "
                  "(zero-cost adaptation)")
    jax.block_until_ready(vals)
    dt = (time.perf_counter() - t0) / args.rounds
    print(f"scheduler round: {dt*1e3:.1f} ms "
          f"({args.pages/dt/1e6:.1f}M pages/s/host)")

    # decentralized parameter refresh (paper Section 5.2): crawl logs say a
    # cohort changes much more often than assumed -> re-estimate (App. E MLE)
    # and repack only the touched blocks, while the service keeps running.
    cohort = np.asarray(jax.device_get(ids))[: min(256, int(ids.shape[0]))]
    rng = np.random.default_rng(0)
    tau_log = rng.uniform(0.5, 2.0, (cohort.size, 200))
    n_log = rng.poisson(1.5 * tau_log)
    fresh = (rng.uniform(size=tau_log.shape) <
             np.exp(-(0.4 * tau_log + 1.2 * n_log))).astype(np.float32)
    t0 = time.perf_counter()
    q = sched.ingest_crawl_results(cohort, jnp.asarray(tau_log),
                                   jnp.asarray(n_log), jnp.asarray(fresh))
    jax.block_until_ready(sched.round.backend)
    print(f"crawl->estimate->refresh: {cohort.size} pages re-estimated "
          f"(mean precision {float(q.precision.mean()):.2f}, mean Delta "
          f"{float(q.delta.mean()):.2f}) in "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms (block-granular repack)")
    sched.ingest_and_schedule(zero_cis)

    # fault tolerance: snapshot + restore the whole scheduler state,
    # including the backend warm-start state (per-shard thresholds, bounds).
    ckpt.save(args.ckpt, 1, jax.device_get(sched.state_dict()))
    sd, step, _ = ckpt.restore_latest(args.ckpt,
                                      jax.device_get(sched.state_dict()))
    sched.load_state_dict(sd)
    ids, _ = sched.ingest_and_schedule(zero_cis)
    if args.select == "fused":
        frac = float(sched.round.backend.frac_active.mean())
        print(f"checkpoint roundtrip OK (step {step}; first post-restore "
              f"round evaluated {100*frac:.0f}% of blocks — warm start)")
    else:
        print(f"checkpoint roundtrip OK (step {step})")

    # tiered lazy evaluation (paper App. G)
    d = sched.d
    table = sched.table or tables.build_ncis_table(d, n_grid=64)
    tiers = init_tiers(d, block=4096)
    tau = sched.state.tau_elap
    n = sched.state.n_cis
    fracs = []
    for rnd in range(1, 10):
        _, ti, tiers, frac = tiered_select(tau, n, d, table, tiers,
                                           jnp.int32(rnd), 0.05, 1024)
        tau = tau.at[ti].set(0.0) + 0.05
        fracs.append(float(frac))
    print(f"tiered evaluation: {100*(1-np.mean(fracs[2:])):.0f}% of block "
          "evaluations skipped (steady state)")


if __name__ == "__main__":
    main()
