"""Production-shaped scheduler demo: a million-page shard, sharded selection
(fused single-pass select by default), tiered lazy evaluation, elastic
bandwidth, checkpoint/restore.

    PYTHONPATH=src python examples/crawl_at_scale.py [--pages 1048576]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import derive, tables
from repro.sched.service import CrawlScheduler
from repro.sched.tiered import init_tiers, tiered_select
from repro.sim import uniform_instance
from repro import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pages", type=int, default=1 << 20)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--budget", type=float, default=4096.0)
    ap.add_argument("--ckpt", default="/tmp/repro_sched_ckpt")
    ap.add_argument("--select", choices=("fused", "table"), default="fused",
                    help="fused = packed single-pass select (exact); "
                         "table = App. G exposure-table lookup")
    args = ap.parse_args()

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    env = uniform_instance(jax.random.PRNGKey(0), args.pages)
    if args.select == "fused":
        sched = CrawlScheduler(env, mesh, bandwidth=args.budget,
                               table_grid=None, use_fused=True)
    else:
        sched = CrawlScheduler(env, mesh, bandwidth=args.budget, table_grid=64)
    zero_cis = jnp.zeros((args.pages,), jnp.int32)

    print(f"pages={args.pages}, budget={args.budget}/round, "
          f"devices={mesh.size}")
    t0 = time.perf_counter()
    for r in range(args.rounds):
        ids, vals = sched.ingest_and_schedule(zero_cis)
        if r == args.rounds // 2:
            # elastic bandwidth (paper App. D): no recomputation at all
            sched.set_bandwidth(args.budget * 1.5)
            print(f"  round {r}: bandwidth -> {sched.bandwidth} "
                  "(zero-cost adaptation)")
    jax.block_until_ready(vals)
    dt = (time.perf_counter() - t0) / args.rounds
    print(f"scheduler round: {dt*1e3:.1f} ms "
          f"({args.pages/dt/1e6:.1f}M pages/s/host)")

    # fault tolerance: snapshot + restore the whole scheduler state
    ckpt.save(args.ckpt, 1, sched.state_dict())
    sd, step, _ = ckpt.restore_latest(args.ckpt, sched.state_dict())
    sched.load_state_dict(sd)
    print(f"checkpoint roundtrip OK (step {step})")

    # tiered lazy evaluation (paper App. G)
    d = sched.d
    table = sched.table or tables.build_ncis_table(d, n_grid=64)
    tiers = init_tiers(d, block=4096)
    tau = sched.state.tau_elap
    n = sched.state.n_cis
    fracs = []
    for rnd in range(1, 10):
        _, ti, tiers, frac = tiered_select(tau, n, d, table, tiers,
                                           jnp.int32(rnd), 0.05, 1024)
        tau = tau.at[ti].set(0.0) + 0.05
        fracs.append(float(frac))
    print(f"tiered evaluation: {100*(1-np.mean(fracs[2:])):.0f}% of block "
          "evaluations skipped (steady state)")


if __name__ == "__main__":
    main()
