"""Quickstart: the paper's algorithm in 60 lines.

Builds a noisy-CIS crawling problem, solves the optimal continuous policy
(Theorem 1), runs the scalable discrete policy (Algorithm 1) with and without
CIS-awareness, and prints the accuracy comparison — the paper's Fig. 3/4
story on one screen.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import policies as pol
from repro.core import solver
from repro.sim import SimConfig, simulate, uniform_instance


def main():
    m, bandwidth, horizon = 200, 100, 100
    key = jax.random.PRNGKey(0)

    # Pages: change rate Delta, importance mu ~ U(0,1); CIS recall
    # lam ~ Beta(.25,.25) (bimodal), false-positive rate nu ~ U(.1,.6).
    env = uniform_instance(key, m)

    # Optimal continuous policy (nested bisection on Theorem 1).
    sol = solver.solve_continuous(env, bandwidth)
    print(f"continuous optimum (with CIS):    {float(sol.objective):.4f}")
    sol0 = solver.solve_continuous_nocis(env, bandwidth)
    print(f"continuous optimum (no CIS):      {float(sol0.objective):.4f}")

    # Discrete greedy policies (Algorithm 1): one crawl per tick 1/R.
    cfg = SimConfig(dt=1.0 / bandwidth, n_steps=bandwidth * horizon)
    for kind, label in [
        (pol.GREEDY, "GREEDY (ignores CIS)"),
        (pol.GREEDY_CIS, "GREEDY-CIS (trusts CIS blindly)"),
        (pol.G_NCIS_APPROX_2, "G-NCIS-APPROX-2"),
        (pol.GREEDY_NCIS, "GREEDY-NCIS (the paper)"),
    ]:
        res = simulate(jax.random.fold_in(key, hash(kind) % 2**31), env,
                       kind, cfg)
        print(f"{label:34s}: {float(res.accuracy):.4f}  "
              f"({int(res.crawl_counts.sum())} crawls)")


if __name__ == "__main__":
    main()
