"""End-to-end driver: train a ~100M-class LM for a few hundred steps on a
crawl-refreshed corpus — the paper's scheduler acting as the data-freshness
layer of the training pipeline.

    PYTHONPATH=src python examples/train_fresh_lm.py \
        --arch smollm-135m --steps 300 [--full-size]

By default the assigned architecture is reduced to laptop scale; --full-size
uses the real config (needs accelerators).
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import configs
from repro.configs.base import reduced
from repro.data import CrawlRefreshedCorpus
from repro.models import model as M
from repro.optim import cosine_schedule, make_optimizer
from repro.train.step import TrainState, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    corpus = CrawlRefreshedCorpus(
        m=2048, vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        refresh_per_step=16, dt=0.05,
    )
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg, max_seq=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    opt = make_optimizer(cfg.optimizer,
                         cosine_schedule(3e-3, 20, args.steps))
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.int32(0))

    # fault tolerance: auto-resume from the latest checkpoint
    restored, step0, _ = ckpt.restore_latest(args.ckpt_dir, state)
    if restored is not None:
        state = restored
        print(f"resumed from step {step0}")

    step_fn = jax.jit(functools.partial(train_step, cfg, opt))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, corpus of {corpus.m} "
          "crawl-refreshed docs")
    t0 = time.perf_counter()
    for i in range(int(state.step), args.steps):
        batch, bstats = corpus.batch_at(i)
        state, metrics = step_fn(state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            cstats = corpus.stats()
            print(f"step {i:4d} loss {float(metrics['loss']):.3f} "
                  f"gnorm {float(metrics['gnorm']):.2f} "
                  f"batch_fresh {bstats['batch_fresh_frac']:.2f} "
                  f"corpus_fresh {cstats['weighted_freshness']:.2f}")
        if i and i % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i, state)
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
